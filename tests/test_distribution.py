"""Distribution-layer integration tests.

These need >1 XLA device, so they run in a subprocess with
xla_force_host_platform_device_count=8 (the main test process keeps the
single-device view per the dry-run isolation rule)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, reduce_config, ShapeConfig
from repro.launch.steps import (input_specs, make_serve_step, make_train_step,
                                opt_struct, param_struct, serve_cache_struct)
from repro.parallel import (batch_shardings, cache_shardings, param_shardings,
                            set_active_mesh)
from repro.launch import roofline as rf
from repro.models import identity_dispatch, init_params, train_loss

mesh = jax.make_mesh((2, 4), ("data", "model"))
set_active_mesh(mesh)

# ---- 1. sharded train-step lower+compile for a dense arch
cfg = reduce_config(get_config("glm4-9b"))
pstruct = param_struct(cfg)
pshard = param_shardings(pstruct, mesh)
step, opt = make_train_step(cfg, chunk=64)
ostruct = opt_struct(cfg, opt, pstruct)
oshard = param_shardings(ostruct, mesh)
specs = input_specs(cfg, ShapeConfig("t", 128, 8, "train"))
bshard = batch_shardings(specs["batch"], mesh)
with mesh:
    compiled = jax.jit(step, in_shardings=(pshard, oshard, bshard)).lower(
        pstruct, ostruct, specs["batch"]).compile()
colls = rf.collective_stats(compiled.as_text())
assert colls["all-reduce"]["count"] > 0, "expected gradient all-reduces"
print("MARK train_lowering_ok")

# ---- 2. sharded decode lower+compile with cache shardings
sstep = make_serve_step(cfg, chunk=64)
cstruct = serve_cache_struct(cfg, 8, 256)
cshard = cache_shardings(cstruct, mesh)
dspec = input_specs(cfg, ShapeConfig("d", 256, 8, "decode"))
tsh = batch_shardings({"tokens": dspec["tokens"],
                       "positions": dspec["positions"]}, mesh)
with mesh:
    jax.jit(sstep, in_shardings=(pshard, cshard, tsh["tokens"],
                                 tsh["positions"])).lower(
        pstruct, cstruct, dspec["tokens"], dspec["positions"]).compile()
print("MARK decode_lowering_ok")

# ---- 3. shard_map MoE == local MoE numerically (real execution)
cfgm = reduce_config(get_config("qwen3-moe-30b-a3b"), dtype="float32")
disp = identity_dispatch(cfgm.moe.num_experts, 4)
set_active_mesh(None)
params = init_params(cfgm, jax.random.PRNGKey(0), moe_dispatch=disp)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                      cfgm.vocab_size),
         "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 64), 0,
                                       cfgm.vocab_size)}
l_local, _ = jax.jit(lambda p, b: train_loss(cfgm, p, b, moe_dispatch=disp,
                                             chunk=32))(params, batch)
set_active_mesh(mesh)
with mesh:
    l_dist, _ = jax.jit(lambda p, b: train_loss(cfgm, p, b, moe_dispatch=disp,
                                                chunk=32))(params, batch)
assert abs(float(l_local) - float(l_dist)) < 5e-3, (l_local, l_dist)
print("MARK moe_parity_ok")

# ---- 4. elastic remesh: values survive a mesh change
from repro.runtime import elastic_remesh
set_active_mesh(None)
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
state = {"blocks": {"wq": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
moved = elastic_remesh(state, mesh_b)
np.testing.assert_array_equal(np.asarray(moved["blocks"]["wq"]),
                              np.asarray(state["blocks"]["wq"]))
print("MARK elastic_ok")

# ---- 5. multi-pod mesh axes exist and shard the pod dimension
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
set_active_mesh(mesh3)
pshard3 = param_shardings(param_struct(cfg), mesh3)
specs3 = input_specs(cfg, ShapeConfig("t", 128, 8, "train"))
bsh3 = batch_shardings(specs3["batch"], mesh3)
assert "pod" in str(bsh3["tokens"].spec)
step3, opt3 = make_train_step(cfg, chunk=64)
osh3 = param_shardings(opt_struct(cfg, opt3, param_struct(cfg)), mesh3)
with mesh3:
    jax.jit(step3, in_shardings=(pshard3, osh3, bsh3)).lower(
        param_struct(cfg), opt_struct(cfg, opt3, param_struct(cfg)),
        specs3["batch"]).compile()
print("MARK multipod_ok")
"""


@pytest.mark.slow
def test_distribution_stack():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    out = proc.stdout
    assert proc.returncode == 0, f"stdout:\n{out}\nstderr:\n{proc.stderr[-3000:]}"
    for mark in ("train_lowering_ok", "decode_lowering_ok", "moe_parity_ok",
                 "elastic_ok", "multipod_ok"):
        assert f"MARK {mark}" in out, f"missing {mark}\n{out}"
