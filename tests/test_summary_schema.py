"""Schema snapshots: the exact key sets of `SimulationResult.summary()` and
of every committed ``BENCH_*.json`` row are FROZEN here.

Downstream consumers (the benchmark CSVs, the README tables, external
dashboards scraping the Prometheus export) key on these names; renaming or
dropping one is a breaking change that must show up in review as an edit
to this file, not as a silent drift.  Adding a key is also caught — extend
the frozen set in the same PR that adds it.
"""

import glob
import json
import os

import pytest

from repro import flags
from repro.core import (
    ALGORITHMS,
    Hypergraph,
    PlacementService,
    Simulator,
    random_workload,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ------------------------------------------------- summary() key snapshots
BASE_KEYS = {
    "algorithm", "avg_span", "max_span", "energy_kj", "shipped_gb", "rf",
    "placement_s", "load_imbalance", "active_machines", "cluster_power_w",
}
LMBR_FIT_KEYS = {
    "fit_moves", "fit_gain_calls", "fit_gain_cache_hits", "fit_gain_fp_hits",
    "fit_peel_pairs", "fit_peel", "fit_gain_cache", "fit_lmbr_epochs",
    "fit_cache_hit_rate", "fit_cover_engine",
}
ONLINE_KEYS = {
    "served_queries", "microbatches", "plan_swaps", "degraded_queries",
    "partitions_down", "repaired_items", "unrepairable_items",
}
DRIFT_KEYS = {"drift_fires", "refits", "windowed_avg_span"}
HEALTH_KEYS = {"alerts_fired", "alerts_resolved"}
MIGRATION_KEYS = {
    "migrations", "migration_copies", "migration_drops", "migration_ticks",
    "migration_done", "migration_transfer_gb", "migration_wasted_gb",
    "migration_max_inflight_gb",
}


@pytest.fixture(autouse=True)
def _reset_flags():
    flags.reset()
    yield
    flags.reset()


def test_offline_summary_exact_keys():
    wl = random_workload(num_items=120, num_queries=300, density=5, seed=4)
    res = Simulator(8, 32).run(wl.hypergraph, ALGORITHMS["lmbr"],
                               name="lmbr", seed=0, max_moves=40)
    assert set(res.summary()) == BASE_KEYS | LMBR_FIT_KEYS


def test_online_summary_exact_keys():
    wl = random_workload(num_items=120, num_queries=300, density=5, seed=4)
    res = Simulator(8, 32).run_online(wl.hypergraph, ALGORITHMS["lmbr"],
                                      name="lmbr", seed=0, max_moves=40)
    assert set(res.summary()) == BASE_KEYS | LMBR_FIT_KEYS | ONLINE_KEYS


def test_online_drift_migration_summary_exact_keys():
    """The maximal summary: drift service armed (drops fit_* — the service
    owns the fitter) plus a paced migration."""
    old = random_workload(num_items=120, num_queries=500, density=6, seed=2)
    new = random_workload(num_items=120, num_queries=500, density=6, seed=9)
    trace = Hypergraph.from_edges(
        [old.hypergraph.edge(e) for e in range(200)]
        + [new.hypergraph.edge(e) for e in range(500)],
        num_nodes=120,
    )
    target = ALGORITHMS["lmbr"](old.hypergraph, 10, 30, seed=1, max_moves=40)
    flags.set_variant("driftw128+driftth1.1+routermb64")
    flags.FLAGS["migration_bandwidth"] = 5.0
    res = Simulator(10, 30).run_online(
        old.hypergraph, ALGORITHMS["hpa"], name="hpa+drift", trace=trace,
        events=[(20, "down", 3), (60, "up", 3), (100, "migrate", target)],
        service=PlacementService("lmbr", seed=0), refit_moves=128, seed=0,
    )
    assert set(res.summary()) == (
        BASE_KEYS | ONLINE_KEYS | DRIFT_KEYS | MIGRATION_KEYS)


def test_online_health_summary_exact_keys():
    """Health monitoring adds exactly the two alert counters (PR 10)."""
    wl = random_workload(num_items=120, num_queries=300, density=5, seed=4)
    flags.set_variant("obscounters+obssnap100+obshealth1")
    res = Simulator(8, 32).run_online(wl.hypergraph, ALGORITHMS["lmbr"],
                                      name="lmbr", seed=0, max_moves=40)
    assert set(res.summary()) == (
        BASE_KEYS | LMBR_FIT_KEYS | ONLINE_KEYS | HEALTH_KEYS)


# ------------------------------------------------ BENCH_*.json row schemas
# union of row keys per committed benchmark artifact (rows within one file
# legitimately differ by section; the union is the stable contract)
BENCH_SCHEMAS = {
    "BENCH_energy.json": {
        "active_machines", "avg_span", "cluster_power_w",
        "durability_copies", "durability_eps", "identical", "items",
        "machine_cut_pct", "mode", "p_loss_max", "partitions", "queries",
        "rf", "seconds", "section", "span_ratio", "tier",
    },
    "BENCH_lmbr.json": {
        "avg_span", "cache_hits", "engine", "gain_calls", "identical",
        "infeasible", "moves", "seconds", "speedup", "tier",
    },
    "BENCH_migration.json": {
        "avg_span", "bit_identical", "copies", "degraded", "done", "drops",
        "engine", "inflight_bound_gb", "max_inflight_gb", "seconds",
        "section", "span_regret", "ticks", "transfer_gb", "wasted_gb",
    },
    "BENCH_online.json": {
        "avg_span", "cold_avg_span", "drift_fires", "engine", "identical",
        "kills", "load_imbalance", "plan_swaps", "qps", "ratio",
        "repaired_items", "restored_coverage", "seconds", "section",
        "speedup", "windowed_avg_span", "worst_ratio",
    },
    "BENCH_obs.json": {
        "avg_span", "events", "gate", "identical", "level", "qps", "ratio",
        "seconds", "section", "series",
    },
    "BENCH_scale.json": {
        "avg_span", "boundary_cost", "boundary_edges", "engine",
        "engine_speedup", "identical", "infeasible", "items", "queries",
        "ratio", "seconds", "section", "shards", "speedup", "tier",
        "workers",
    },
    "BENCH_spans.json": {
        "avg_span", "circuit", "edges", "engine", "seconds", "speedup",
    },
}


def test_bench_artifacts_match_frozen_schemas():
    found = {os.path.basename(p)
             for p in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json"))}
    unknown = found - set(BENCH_SCHEMAS)
    assert not unknown, f"new BENCH artifacts need a frozen schema: {unknown}"
    for name in sorted(found):
        rows = json.load(open(os.path.join(REPO_ROOT, name)))
        assert rows, f"{name} is empty"
        keys = set()
        for r in rows:
            keys |= set(r)
        assert keys == BENCH_SCHEMAS[name], (
            f"{name} row schema drifted: "
            f"+{sorted(keys - BENCH_SCHEMAS[name])} "
            f"-{sorted(BENCH_SCHEMAS[name] - keys)}"
        )
