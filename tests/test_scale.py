"""Tests for the cluster-scale pipeline (repro.scale): streaming trace
ingestion, workload sharding, parallel per-shard fits + merge + boundary
repair, and the scale flag surface."""

import numpy as np
import pytest

from repro import flags
from repro.core import (
    ALGORITHMS,
    Hypergraph,
    PlacementService,
    canonicalize_csr,
    random_workload,
    spans_for_workload,
    web_scale_chunks,
    web_scale_workload,
)
from repro.scale import (
    StreamingHypergraphBuilder,
    connected_components,
    fit_sharded_placement,
    shard_workload,
)


def _random_queries(rng, num_items, n, with_dups=True):
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 9))
        q = rng.integers(0, num_items, size=k)
        if not with_dups:
            q = np.unique(q)
        out.append(q)
    return out


# ------------------------------------------------------------------ stream
def test_canonicalize_csr_matches_per_edge_unique():
    rng = np.random.default_rng(0)
    queries = _random_queries(rng, 40, 300)
    ptr = np.zeros(len(queries) + 1, dtype=np.int64)
    ptr[1:] = np.cumsum([len(q) for q in queries])
    nodes = np.concatenate(queries)
    cptr, cnodes = canonicalize_csr(ptr, nodes)
    for i, q in enumerate(queries):
        assert np.array_equal(cnodes[cptr[i]: cptr[i + 1]], np.unique(q))


def test_streaming_builder_equals_dict_builder():
    """Chunked streaming build == Hypergraph.from_edges bit-for-bit:
    edge order, per-edge pin dedup + sort, weights, dtypes."""
    rng = np.random.default_rng(1)
    queries = _random_queries(rng, 80, 700)
    weights = rng.uniform(0.5, 3.0, size=len(queries))
    ref = Hypergraph.from_edges(queries, num_nodes=80, edge_weights=weights)
    builder = StreamingHypergraphBuilder(80)
    for lo in range(0, len(queries), 123):  # uneven chunks
        builder.add_queries(queries[lo: lo + 123], weights[lo: lo + 123])
    got = builder.build()
    assert got.equals(ref)
    assert got.edge_ptr.dtype == ref.edge_ptr.dtype
    assert got.edge_nodes.dtype == ref.edge_nodes.dtype


def test_streaming_builder_csr_chunks_and_rebuild():
    """add_csr ingests raw CSR chunks (duplicate pins allowed); build() is
    non-destructive, so appending more chunks extends the trace."""
    rng = np.random.default_rng(2)
    q1 = _random_queries(rng, 30, 100)
    q2 = _random_queries(rng, 30, 50)
    builder = StreamingHypergraphBuilder(30)
    ptr = np.zeros(len(q1) + 1, dtype=np.int64)
    ptr[1:] = np.cumsum([len(q) for q in q1])
    builder.add_csr(ptr, np.concatenate(q1))
    assert builder.build().equals(Hypergraph.from_edges(q1, num_nodes=30))
    builder.add_queries(q2)
    assert builder.build().equals(
        Hypergraph.from_edges(q1 + q2, num_nodes=30)
    )
    assert builder.num_chunks == 2


def test_streaming_builder_merges_duplicates_like_dict():
    """merge_duplicates=True == the dict reference: unique canonical edges
    in first-seen order, weights summed in arrival order."""
    rng = np.random.default_rng(3)
    base = _random_queries(rng, 12, 60)  # small universe -> many duplicates
    weights = rng.uniform(0.1, 2.0, size=len(base))
    builder = StreamingHypergraphBuilder(12, merge_duplicates=True)
    for lo in range(0, len(base), 17):
        builder.add_queries(base[lo: lo + 17], weights[lo: lo + 17])
    got = builder.build()
    seen: dict[tuple, float] = {}
    order: list[tuple] = []
    for q, w in zip(base, weights):
        key = tuple(np.unique(np.asarray(q, dtype=np.int64)))
        if key in seen:
            seen[key] += float(w)
        else:
            seen[key] = float(w)
            order.append(key)
    assert got.num_edges == len(order)
    for i, key in enumerate(order):
        assert tuple(got.edge(i)) == key
        assert got.edge_weights[i] == seen[key]


def test_streaming_builder_rejects_bad_chunks():
    builder = StreamingHypergraphBuilder(10)
    with pytest.raises(ValueError):
        builder.add_queries([[0, 10]])  # pin out of range
    with pytest.raises(ValueError):
        builder.add_queries([[0, -1]])
    with pytest.raises(ValueError):
        builder.add_queries([[0, 1]], edge_weights=[1.0, 2.0])


def test_web_scale_workload_small_params():
    # chunk size shapes the RNG stream, so rebuilds must chunk identically
    wl = web_scale_workload(num_items=500, num_queries=2000, num_clusters=16,
                            seed=0, chunk=512)
    hg = wl.hypergraph
    assert hg.num_nodes == 500 and hg.num_edges == 2000
    assert hg.edge_nodes.min() >= 0 and hg.edge_nodes.max() < 500
    sizes = hg.edge_sizes()
    assert sizes.min() >= 1 and sizes.max() <= 8
    # generator chunks == built hypergraph through the builder
    b = StreamingHypergraphBuilder(500)
    for ptr, pins in web_scale_chunks(num_items=500, num_queries=2000,
                                      num_clusters=16, seed=0, chunk=512):
        b.add_csr(ptr, pins)
    assert b.build().equals(hg)


# ----------------------------------------------------------------- sharder
def test_connected_components_matches_bruteforce():
    rng = np.random.default_rng(4)
    queries = _random_queries(rng, 60, 25)
    hg = Hypergraph.from_edges(queries, num_nodes=60)
    labels = connected_components(hg)
    # brute-force union-find
    parent = list(range(60))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in range(hg.num_edges):
        pins = hg.edge(e)
        for u in pins[1:]:
            ra, rb = find(int(pins[0])), find(int(u))
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)
    want = np.array([find(v) for v in range(60)])
    assert np.array_equal(labels, want)


def test_shard_workload_accounting():
    wl = random_workload(num_items=300, num_queries=1000, density=5, seed=5)
    hg = wl.hypergraph
    plan = shard_workload(hg, num_partitions=12, capacity=60, num_shards=4)
    assert plan.num_shards == 4
    # every item homed exactly once; shard item lists match the map
    assert plan.item_shard.shape == (300,)
    for s, spec in enumerate(plan.shards):
        assert np.array_equal(spec.items, np.flatnonzero(plan.item_shard == s))
        assert spec.sub_hg.num_nodes == len(spec.items)
        # every sub-edge has >= 1 pin, fragments were trimmed to >= 2
        if spec.sub_hg.num_edges:
            assert spec.sub_hg.edge_sizes().min() >= 1
    # partition budget: exact split, each shard feasible
    n_parts = np.diff(plan.part_offset)
    assert n_parts.sum() == 12
    for spec, n in zip(plan.shards, n_parts):
        assert spec.weight <= n * 60 + 1e-9
    # boundary edges are exactly those whose pins span > 1 shard
    pin_shards = plan.item_shard[hg.edge_nodes]
    want_boundary = [
        e for e in range(hg.num_edges)
        if len(set(pin_shards[hg.edge_ptr[e]: hg.edge_ptr[e + 1]])) > 1
    ]
    assert np.array_equal(plan.boundary_edges, want_boundary)
    lam = np.array([
        len(set(pin_shards[hg.edge_ptr[e]: hg.edge_ptr[e + 1]]))
        for e in want_boundary
    ])
    assert np.array_equal(plan.boundary_lambda, lam)
    assert plan.boundary_cost == pytest.approx(
        float((hg.edge_weights[plan.boundary_edges] * (lam - 1)).sum())
    )


def test_shard_workload_separates_components():
    """Two co-access islands + one bridge query: the islands land on
    different shards and only the bridge is a boundary edge."""
    qs = [[0, 1], [1, 2], [0, 2], [3, 4], [4, 5], [3, 5], [2, 3]]
    hg = Hypergraph.from_edges(qs, num_nodes=6)
    # one component (the bridge connects them): force a 2-shard cut
    plan = shard_workload(hg, num_partitions=2, capacity=4, num_shards=2)
    assert plan.num_shards == 2
    assert len(plan.boundary_edges) >= 1
    # without the bridge, components separate perfectly: no boundary
    hg2 = Hypergraph.from_edges(qs[:-1], num_nodes=6)
    plan2 = shard_workload(hg2, num_partitions=2, capacity=4, num_shards=2)
    assert plan2.num_components == 2
    assert len(plan2.boundary_edges) == 0
    assert plan2.boundary_cost == 0.0


def test_shard_workload_infeasible_budget_raises():
    wl = random_workload(num_items=100, num_queries=200, density=5, seed=0)
    with pytest.raises(ValueError):
        shard_workload(wl.hypergraph, num_partitions=2, capacity=10,
                       num_shards=2)


# ------------------------------------------------------------ parallel fit
@pytest.fixture(scope="module")
def clustered_wl():
    return web_scale_workload(num_items=800, num_queries=4000,
                              num_clusters=16, cross_frac=0.05, seed=7)


def test_fit_sharded_serial_equals_pool(clustered_wl):
    """Worker count never changes the fitted placement: the pooled run is
    bit-identical to the deterministic serial fallback."""
    hg = clustered_wl.hypergraph
    serial = fit_sharded_placement(hg, 16, 110, num_shards=4, workers=1,
                                   seed=0, max_moves=40)
    pooled = fit_sharded_placement(hg, 16, 110, num_shards=4, workers=3,
                                   seed=0, max_moves=40)
    assert (serial.member == pooled.member).all()
    assert serial.stats["used_pool"] is False
    serial.placement.validate()


def test_fit_sharded_service_entry_point(clustered_wl):
    hg = clustered_wl.hypergraph
    svc = PlacementService("lmbr", seed=0)
    plan = svc.fit_sharded(hg, num_partitions=16, capacity=110, num_shards=4,
                           workers=1, max_moves=40)
    assert plan.algorithm == "lmbr+sharded"
    assert plan.member.shape == (16, 800)
    assert plan.stats["shards"] == 4
    assert plan.stats["boundary_edges"] >= 0
    # spans are computable for the whole trace (placement covers all items)
    spans = spans_for_workload(hg, plan.as_placement())
    assert len(spans) == hg.num_edges and (spans >= 1).all()
    # flags drive the defaults the same way the kwargs do
    flags.set_variant("shards4+scalew1+brepair64")
    try:
        via_flags = svc.fit_sharded(hg, num_partitions=16, capacity=110,
                                    max_moves=40, boundary_repair=None)
    finally:
        flags.reset()
    assert via_flags.stats["shards"] == 4


def test_fit_sharded_quality_near_monolithic(clustered_wl):
    """On a clustered mid-size workload the sharded fit's avg span stays
    close to the monolithic fit (the bench gates 1.05 on its mid tier; the
    test tier is smaller, so allow a looser 1.15)."""
    hg = clustered_wl.hypergraph
    mono = ALGORITHMS["lmbr"](hg, 16, 110, seed=0, max_moves=160)
    sharded = fit_sharded_placement(hg, 16, 110, num_shards=4, workers=1,
                                    seed=0, max_moves=80)
    mono_span = float(spans_for_workload(hg, mono).mean())
    shard_span = float(spans_for_workload(hg, sharded.placement).mean())
    assert shard_span <= 1.15 * mono_span, (shard_span, mono_span)


def test_boundary_repair_capacity_safety_near_full():
    """Adversarial near-full layout: partitions have almost no free space,
    so the boundary repair pass must place little-to-nothing and NEVER
    violate capacity."""
    wl = web_scale_workload(num_items=600, num_queries=3000, num_clusters=8,
                            cross_frac=0.2, seed=11)
    hg = wl.hypergraph
    # shard weights here are [166, 166, 166, 102]; at capacity 84 three of
    # the four shards have 2 units of free space across 2 partitions each —
    # the repair pass has cross-shard pressure (1400+ boundary edges) but
    # almost nowhere to put copies
    res = fit_sharded_placement(hg, 8, 84, num_shards=4, workers=1, seed=0,
                                max_moves=40, boundary_repair=200)
    res.placement.validate()  # would raise on any over-capacity row
    assert (res.placement.partition_weights() <= 84 + 1e-9).all()
    # and disabling the pass is allowed
    res0 = fit_sharded_placement(hg, 8, 84, num_shards=4, workers=1, seed=0,
                                 max_moves=40, boundary_repair=0)
    assert res0.stats["repair_moves"] == 0


# ------------------------------------------------------------------- flags
def test_scale_flag_variants():
    flags.set_variant("shards16+scalew4+brepair128")
    try:
        assert flags.FLAGS["scale_shards"] == 16
        assert flags.FLAGS["scale_workers"] == 4
        assert flags.FLAGS["scale_boundary_repair"] == 128
    finally:
        flags.reset()
    for bad in ("scalew0", "brepair-1"):
        with pytest.raises(ValueError):
            flags.set_variant(bad)
    flags.reset()
