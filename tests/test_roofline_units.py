"""Unit tests for the roofline derivation and dry-run plumbing (no devices)."""

import numpy as np
import pytest

from repro.configs import SHAPE_GRID, get_config
from repro.launch import roofline as rf
from repro.launch.steps import input_specs, shape_skip_reason


def test_collective_stats_parses_hlo():
    hlo = """
  %all-gather.20 = f32[8,64,32]{2,1,0} all-gather(%x), channel_id=1
  %ar = (f32[256,512]{1,0}, f32[256,512]{1,0}) all-reduce(%a, %b), channel_id=2
  %a2a.1 = bf16[16,128]{1,0} all-to-all(%y), channel_id=3
  %ag-start = f32[4]{0} all-gather-start(%z), channel_id=4
  %ag-done = f32[4]{0} all-gather-done(%ag-start)
  %not-a-coll = f32[4]{0} add(%p, %q)
"""
    st = rf.collective_stats(hlo)
    assert st["all-gather"]["count"] == 2  # plain + start (done not counted)
    assert st["all-gather"]["bytes"] == 8 * 64 * 32 * 4 + 16
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 2 * 256 * 512 * 4
    assert st["all-to-all"]["bytes"] == 16 * 128 * 2
    assert st["collective-permute"]["count"] == 0


def test_roofline_terms_and_dominance():
    r = rf.roofline(flops_per_dev=197e12, bytes_per_dev=819e9 / 2,
                    coll_bytes_per_dev=0, chips=256)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(0.5)
    assert r["dominant"] == "compute"
    assert r["roofline_fraction"] == pytest.approx(1.0)
    r2 = rf.roofline(1e12, 1e9, 500e9, chips=256)
    assert r2["dominant"] == "collective"
    assert r2["roofline_fraction"] < 0.01


def test_shape_skips_match_design():
    quadratic = ["seamless-m4t-medium", "internvl2-2b", "glm4-9b",
                 "nemotron-4-15b", "olmo-1b", "deepseek-v3-671b",
                 "qwen3-moe-30b-a3b"]
    subq = ["h2o-danube-1.8b", "mamba2-2.7b", "hymba-1.5b"]
    long = SHAPE_GRID["long_500k"]
    for a in quadratic:
        assert shape_skip_reason(get_config(a), long) is not None, a
    for a in subq:
        assert shape_skip_reason(get_config(a), long) is None, a
    # nothing else skips
    for a in quadratic + subq:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_skip_reason(get_config(a), SHAPE_GRID[s]) is None


def test_input_specs_shapes():
    cfg = get_config("glm4-9b")
    tr = input_specs(cfg, SHAPE_GRID["train_4k"])
    assert tr["batch"]["tokens"].shape == (256, 4096)
    de = input_specs(cfg, SHAPE_GRID["decode_32k"])
    assert de["tokens"].shape == (128, 1)
    cfg_a = get_config("seamless-m4t-medium")
    pre = input_specs(cfg_a, SHAPE_GRID["prefill_32k"])
    assert pre["batch"]["frontend"].shape == (32, 1024, 1024)


def test_model_flops_sane():
    cfg = get_config("glm4-9b")
    n = cfg.param_count()
    assert 8e9 < n < 11e9, f"glm4-9b param count {n/1e9:.2f}B"
    tr = rf.model_flops(cfg, SHAPE_GRID["train_4k"], True)
    assert tr == pytest.approx(6 * n * 256 * 4096)
    ds = get_config("deepseek-v3-671b")
    assert 6e11 < ds.param_count() < 7.5e11, ds.param_count() / 1e9
    assert ds.active_param_count() < 0.1 * ds.param_count()
    q3 = get_config("qwen3-moe-30b-a3b")
    assert 2.5e10 < q3.param_count() < 3.5e10, q3.param_count() / 1e9
    assert 2e9 < q3.active_param_count() < 4.5e9, q3.active_param_count() / 1e9


def test_all_arch_param_counts_match_names():
    expect = {
        "olmo-1b": (0.9e9, 1.6e9),
        "hymba-1.5b": (1.2e9, 2.1e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "internvl2-2b": (1.5e9, 2.6e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "nemotron-4-15b": (13e9, 18e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
