"""Property-based-testing shim: the real `hypothesis` package when the
environment has it, the vendored deterministic `_hypothesis_stub` otherwise.

Test modules import the strategy surface from here instead of repeating the
try/except fallback at every site, so installing hypothesis upgrades every
property test to real shrinking/example-generation at once while offline
containers keep running on the stub.  Only the API subset the stub mirrors
is allowed through this shim: ``given``, ``settings`` (``max_examples``,
``deadline``), and ``st.integers / floats / lists / data``.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # offline container: vendored deterministic fallback
    from _hypothesis_stub import (  # noqa: F401
        given,
        settings,
        strategies as st,
    )

    HAVE_HYPOTHESIS = False

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
