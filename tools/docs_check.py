"""docs-check: keep README/ARCHITECTURE/benchmarks docs honest.

Checks, for README.md, docs/ARCHITECTURE.md and benchmarks/README.md:

  1. every ```bash code-block command is real: `make <target>` targets exist
     in the Makefile, `python -m <module>` modules resolve (with src/ on the
     path), `python <script>` files exist;
  2. every ```python code block actually runs (executed with src/ on
     sys.path — keep doc snippets small and fast);
  3. every backticked flag-ish token (`span_*`, `lmbr_*`, `mla_*`, ...)
     names a real `repro.flags.FLAGS` key, and every backticked variant
     component (e.g. `spanjax`, `peelreference+lmbrcache0`) parses through
     `repro.flags.set_variant`;
  4. every relative markdown link points at an existing file.

Exit code 0 = docs are consistent with the code.  Run via `make docs-check`
(part of `make ci`).
"""

from __future__ import annotations

import importlib.util
import re
import shlex
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/ARCHITECTURE.md", "benchmarks/README.md"]

sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))  # for `benchmarks.*` modules

from repro import flags  # noqa: E402

FLAG_PREFIXES = ("span_", "lmbr_", "mla_", "moe_", "accum_", "sp_",
                 "router_", "drift_", "scale_", "placement_", "durability_",
                 "node_", "migration_", "obs_", "health_")
# flag-prefixed identifiers that are NOT flags (kernel / bench row names,
# serving counters, profile columns, API parameters)
NON_FLAGS = {"span_gain", "span_gain_calibration", "span_gain_ref",
             "span_gain_tile", "span_round_calibration", "drift_fires",
             "node_weights", "node_cost", "placement_seconds",
             "placement_stats", "durability_copies", "durability_eps=0",
             "placement_s", "placement_applications", "span_ratio",
             "span_regret",
             "migration_copies", "migration_drops", "migration_ticks",
             "migration_done", "migration_transfer_gb",
             "migration_wasted_gb", "migration_max_inflight_gb",
             # metric / trace-event series names (repro.obs), not flags
             "router_microbatch_seconds", "router_partition_load",
             "router_plan_swaps_total", "router_served_queries_total",
             "router_microbatches_total", "migration_transferred",
             "migration_wasted", "migration_inflight",
             "migration_transferred_total", "migration_wasted_total",
             "migration_copies_total", "migration_drops_total",
             "drift_fires_total", "drift_refits_total", "lmbr_moves",
             "health_alerts_fired_total", "health_alerts_resolved_total",
             "health_alerts_active"}
# backticked tokens that should parse as --variant specs
VARIANT_RE = re.compile(
    r"^(baseline|mla_decomp|sp2?|accum\d+|cf[\d.]+|spanth\d+|peelth\d+|"
    r"span(auto|numpy|jax|pallas)|spanroundth\d+|"
    r"spanround(auto|numpy|device)|"
    r"peel(vector|reference|auto|device|pallas)|"
    r"lmbrcache[01]|lmbrepoch(item|partition)|"
    r"routerbal[01]|routermb\d+|routereps[\d.]+|"
    r"driftw\d+|driftth[\d.]+|shards\d+|scalew\d+|brepair\d+|"
    r"migbw[\d.]+|migconc\d+|mighead[\d.]+|"
    r"obshealth[01]|obs(off|counters|trace)|obssnap\d+|"
    r"healthw\d+|healthhyst\d+|healthspan[\d.]+|healthp99[\d.]+|"
    r"healthdeg[\d.]+|healthskew[\d.]+|healthbacklog[\d.]+|healthz[\d.]+|"
    r"energy|durab[\d.e+-]+|nodecost[\d.]+|routercost[01])"
    r"(\+.+)?$"
)


def fenced_blocks(text: str):
    """Yield (language, body) for every fenced code block."""
    for m in re.finditer(r"```(\w*)\n(.*?)```", text, re.S):
        yield m.group(1), m.group(2)


def check_bash_line(line: str, errors: list[str], ctx: str):
    line = line.strip()
    if not line or line.startswith("#"):
        return
    try:
        toks = shlex.split(line)
    except ValueError:
        errors.append(f"{ctx}: unparseable command {line!r}")
        return
    while toks and re.match(r"^[A-Z_][A-Z0-9_]*=", toks[0]):
        toks = toks[1:]  # strip env-var prefixes like PYTHONPATH=src
    if not toks:
        return
    cmd = toks[0]
    if cmd == "make":
        makefile = (REPO / "Makefile").read_text()
        targets = set(re.findall(r"^([\w-]+):", makefile, re.M))
        for t in toks[1:]:
            if not t.startswith("-") and t not in targets:
                errors.append(f"{ctx}: make target {t!r} not in Makefile")
    elif cmd == "python":
        if len(toks) > 2 and toks[1] == "-m":
            mod = toks[2]
            if importlib.util.find_spec(mod) is None:
                errors.append(f"{ctx}: module {mod!r} does not resolve")
        elif len(toks) > 1 and toks[1].endswith(".py"):
            if not (REPO / toks[1]).exists():
                errors.append(f"{ctx}: script {toks[1]!r} not found")
    # other commands (git, pip, ...) are not emitted by our docs; ignore


def check_python_block(body: str, errors: list[str], ctx: str):
    env = {"__name__": "__docs_check__"}
    try:
        exec(compile(body, ctx, "exec"), env)
    except Exception as exc:  # noqa: BLE001 - report, don't crash the checker
        errors.append(f"{ctx}: python snippet failed: {type(exc).__name__}: {exc}")


def check_inline_tokens(text: str, errors: list[str], ctx: str):
    for tok in re.findall(r"`([^`\n]+)`", text):
        t = tok.strip().strip('"')
        if (re.fullmatch(r"[a-z][a-z0-9_]*", t) and t.startswith(FLAG_PREFIXES)
                and t not in NON_FLAGS):
            if t not in flags.FLAGS and not any(
                k.startswith(t) for k in flags.FLAGS
            ):
                errors.append(f"{ctx}: flag name `{t}` not in repro.flags.FLAGS")
        elif re.fullmatch(r"[a-z0-9_.+]+", t) and "+" in t:
            if VARIANT_RE.match(t):
                try:
                    flags.set_variant(t)
                except ValueError as exc:
                    errors.append(f"{ctx}: variant `{t}` rejected: {exc}")
                finally:
                    flags.reset()


def check_links(text: str, errors: list[str], doc: Path):
    for target in re.findall(r"\]\(([^)#]+?)\)", text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (doc.parent / target).exists() and not (REPO / target).exists():
            errors.append(f"{doc.name}: broken link -> {target}")


def main() -> int:
    errors: list[str] = []
    for rel in DOCS:
        doc = REPO / rel
        if not doc.exists():
            errors.append(f"missing doc: {rel}")
            continue
        text = doc.read_text()
        check_inline_tokens(text, errors, rel)
        check_links(text, errors, doc)
        for lang, body in fenced_blocks(text):
            if lang in ("bash", "sh", "shell"):
                for line in body.splitlines():
                    check_bash_line(line, errors, rel)
            elif lang == "python":
                check_python_block(body, errors, rel)
    # the tier-1 verify line in README must match ROADMAP's contract
    roadmap = (REPO / "ROADMAP.md").read_text()
    m = re.search(r"\*\*Tier-1 verify:\*\* `([^`]+)`", roadmap)
    if m and m.group(1).split("python ")[-1] not in (REPO / "README.md").read_text():
        errors.append("README quickstart does not mention the tier-1 verify command")
    if errors:
        print("docs-check: FAILED")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("docs-check: OK (commands, snippets, flags, links)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
