"""obs-report: render a run report from a trace + optional prom snapshot.

Usage:
    python tools/obs_report.py TRACE [--prom PROM] [--top-k K]

TRACE is a Tracer export — JSONL (``to_jsonl``) or the Chrome JSON object
format (``to_chrome_trace``); both are auto-detected.  PROM is a
Prometheus text exposition (``Registry.to_prom_text``) whose headline
counters get appended to the report.  The analytics live in
``repro.obs.analyze`` (span-tree reconstruction, per-name self/total
aggregation, fit critical path, top-k slowest microbatches, alert log);
this file is only the argv/IO shell, so the same report is available
in-process from a live tracer.

A committed tiny fixture keeps the CLI honest in CI:

    python tools/obs_report.py tools/fixtures/tiny_trace.jsonl \
        --prom tools/fixtures/tiny_prom.txt

runs as part of ``make docs-check``.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.obs import parse_prom_text, render_report, load_events  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_report",
        description="Render a plain-text run report from a Chrome-trace "
                    "export (JSONL or JSON) and an optional prom snapshot.",
    )
    ap.add_argument("trace", help="trace file (Tracer.to_jsonl or "
                                  "Tracer.to_chrome_trace output)")
    ap.add_argument("--prom", default=None,
                    help="Prometheus text exposition (Registry.to_prom_text)")
    ap.add_argument("--top-k", type=int, default=5,
                    help="slowest-microbatch rows to show (default 5)")
    args = ap.parse_args(argv)

    try:
        events = load_events(Path(args.trace).read_text())
    except (OSError, ValueError) as exc:
        print(f"obs_report: cannot load trace {args.trace!r}: {exc}",
              file=sys.stderr)
        return 1
    snapshot = None
    if args.prom is not None:
        try:
            snapshot = parse_prom_text(Path(args.prom).read_text())
        except (OSError, ValueError) as exc:
            print(f"obs_report: cannot load prom {args.prom!r}: {exc}",
                  file=sys.stderr)
            return 1
    sys.stdout.write(render_report(events, snapshot, top_k=args.top_k))
    return 0


if __name__ == "__main__":
    sys.exit(main())
